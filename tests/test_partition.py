"""Partitioned graph storage (DESIGN.md §11): CSR shards, halo tiles, and
bit-identical engine runs against the replicated reference layout."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import RunConfig, SuperstepRuntime
from repro.core import graph as G
from repro.core.apps import CliquesApp, FSMApp, MotifsApp
from repro.kernels import gather as gather_lib


# ---------------------------------------------------------------------------
# partition bounds: exact vertex cover, no overlap
# ---------------------------------------------------------------------------

GRAPHS = [
    G.random_labeled(60, 150, 3, seed=0),
    G.random_labeled(40, 220, 3, seed=2),
    G.random_labeled(7, 9, 2, seed=5),
    G.complete(5),
]


@pytest.mark.parametrize("w", [1, 2, 3, 4, 8])
@pytest.mark.parametrize("balance", ["vertex", "degree"])
def test_partition_bounds_cover_no_overlap(w, balance):
    for g in GRAPHS:
        off = np.asarray(G.partition_bounds(g, w, balance))
        assert off.shape == (w + 1,)
        assert off[0] == 0 and off[-1] == g.n
        # monotone non-decreasing boundaries => ranges are disjoint and
        # their union is exactly [0, n): every vertex owned exactly once
        assert (np.diff(off) >= 0).all()
        owner = np.searchsorted(off, np.arange(g.n), side="right") - 1
        assert ((owner >= 0) & (owner < w)).all()
        counts = np.bincount(owner, minlength=w)
        assert counts.sum() == g.n
        assert (counts == np.diff(off)).all()


def test_degree_balance_beats_vertex_split_on_skew():
    # power-law graph: the low-id vertices are heavy; a plain vertex split
    # puts most edge endpoints in shard 0, degree balancing spreads them
    g = G.random_labeled(400, 3000, 3, seed=1)
    deg = np.bincount(np.asarray(g.edges).ravel(), minlength=g.n)
    loads = []
    for balance in ("vertex", "degree"):
        off = np.asarray(G.partition_bounds(g, 8, balance))
        loads.append(
            max(deg[off[s]: off[s + 1]].sum() for s in range(8))
        )
    assert loads[1] < loads[0]


# ---------------------------------------------------------------------------
# shard tables reconstruct the replicated CSR exactly
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("w", [1, 2, 4])
def test_shard_tables_match_replicated(w):
    for g in GRAPHS:
        dg = G.to_device(g)
        pg = G.to_partitioned(g, w)
        off = np.asarray(pg.part_offsets)
        nbr = np.asarray(dg.nbr)
        ned = np.asarray(dg.nbr_eid)
        deg = np.asarray(dg.deg)
        adj = np.asarray(dg.adj_bits)
        for s in range(w):
            lo, hi = off[s], off[s + 1]
            rows = hi - lo
            assert (np.asarray(pg.nbr_sh)[s, :rows] == nbr[lo:hi]).all()
            assert (np.asarray(pg.nbr_eid_sh)[s, :rows] == ned[lo:hi]).all()
            assert (np.asarray(pg.deg_sh)[s, :rows] == deg[lo:hi]).all()
            assert (np.asarray(pg.adj_sh)[s, :rows] == adj[lo:hi]).all()
            # padding rows beyond the owned range stay inert
            assert (np.asarray(pg.nbr_sh)[s, rows:] == -1).all()
            assert (np.asarray(pg.deg_sh)[s, rows:] == 0).all()


def test_partitioned_is_edge_matches_replicated():
    # ids in [-1, n): in-range vertices plus the -1 padding sentinel — the
    # only ids the engine ever queries (>= n is undefined for both layouts)
    rng = np.random.default_rng(7)
    for g in GRAPHS:
        dg = G.to_device(g)
        pg = G.to_partitioned(g, 4)
        u = rng.integers(-1, g.n, size=400).astype(np.int32)
        v = rng.integers(-1, g.n, size=400).astype(np.int32)
        a = np.asarray(dg.is_edge(jnp.asarray(u), jnp.asarray(v)))
        b = np.asarray(pg.is_edge(jnp.asarray(u), jnp.asarray(v)))
        assert (a == b).all()


def test_adjacency_tile_matches_dense_oracle():
    """Satellite: adjacency_bits is built tile-wise in O(m) — verify each
    tile against the dense boolean oracle."""
    for g in GRAPHS:
        dense = np.zeros((g.n, g.n), bool)
        for x, y in np.asarray(g.edges):
            dense[x, y] = dense[y, x] = True
        words = (g.n + 31) // 32
        ref = np.zeros((g.n, words), np.uint32)
        for i in range(g.n):
            for j in np.flatnonzero(dense[i]):
                ref[i, j // 32] |= np.uint32(1) << np.uint32(j % 32)
        assert (np.asarray(g.adjacency_bits()) == ref).all()
        for lo, hi in [(0, g.n), (0, max(1, g.n // 3)), (g.n // 2, g.n)]:
            assert (np.asarray(g.adjacency_tile(lo, hi)) == ref[lo:hi]).all()


def test_per_device_adjacency_bytes_shrink():
    g = G.random_labeled(400, 3000, 3, seed=1)
    dg = G.to_device(g)
    pg = G.to_partitioned(g, 8, balance="vertex")
    assert pg.per_device_adjacency_bytes * 8 <= G.replicated_adjacency_bytes(
        dg
    ) * 1.25  # padded shard rows allow a little slack


# ---------------------------------------------------------------------------
# halo tiles: unique + gather vs numpy oracle
# ---------------------------------------------------------------------------

def test_halo_unique_matches_numpy():
    n = 50
    rng = np.random.default_rng(3)
    verts = rng.integers(-1, n, size=200).astype(np.int32)
    oracle = np.unique(verts[verts >= 0])
    cap = 64
    uniq, count = gather_lib.halo_unique(jnp.asarray(verts), n, cap)
    uniq, count = np.asarray(uniq), int(count)
    assert count == len(oracle)
    assert (uniq[: len(oracle)] == oracle).all()
    assert (uniq[len(oracle):] == n).all()  # sentinel padding at the end


def test_halo_unique_count_unclamped_on_overflow():
    n = 50
    verts = jnp.arange(n, dtype=jnp.int32)
    uniq, count = gather_lib.halo_unique(verts, n, 16)
    assert int(count) == n  # exact observed count, same contract as compact
    assert np.asarray(uniq).shape == (16,)


def test_halo_unique_kernel_matches_ref():
    n = 40
    rng = np.random.default_rng(4)
    verts = rng.integers(-1, n, size=128).astype(np.int32)
    ref = gather_lib.halo_unique(jnp.asarray(verts), n, 64)
    ker = gather_lib.halo_unique(
        jnp.asarray(verts), n, 64, use_kernel=True, interpret=True
    )
    assert (np.asarray(ref[0]) == np.asarray(ker[0])).all()
    assert int(ref[1]) == int(ker[1])


def test_gather_rows_matches_numpy():
    rng = np.random.default_rng(5)
    table = rng.integers(0, 100, size=(30, 7)).astype(np.int32)
    rows = rng.integers(-2, 32, size=50).astype(np.int32)
    oracle = np.full((50, 7), -1, np.int32)
    ok = (rows >= 0) & (rows < 30)
    oracle[ok] = table[rows[ok]]
    got = gather_lib.gather_rows(
        jnp.asarray(table), jnp.asarray(rows), jnp.int32(-1)
    )
    assert (np.asarray(got) == oracle).all()
    ker = gather_lib.gather_rows(
        jnp.asarray(table), jnp.asarray(rows), jnp.int32(-1),
        use_kernel=True, interpret=True,
    )
    assert (np.asarray(ker) == oracle).all()


def test_build_tile_view_contents():
    from repro.core import explore

    g = G.random_labeled(60, 150, 3, seed=0)
    dg = G.to_device(g)
    pg = G.to_partitioned(g, 4)
    rng = np.random.default_rng(6)
    members = rng.integers(0, g.n, size=(16, 2)).astype(np.int32)
    n_valid = np.full(16, 2, np.int32)
    view = explore.build_tile_view(
        pg, jnp.asarray(members), jnp.asarray(n_valid), "vertex"
    )
    uniq = np.asarray(view.uniq)
    touched = np.unique(members)
    k = len(touched)
    assert (uniq[:k] == touched).all() and (uniq[k:] == g.n).all()
    # each gathered row is exactly the owner's replicated CSR row
    nbr, adj = np.asarray(dg.nbr), np.asarray(dg.adj_bits)
    assert (np.asarray(view.nbr_t)[:k] == nbr[touched]).all()
    assert (np.asarray(view.adj_t)[:k] == adj[touched]).all()
    assert (np.asarray(view.nbr_t)[k:] == -1).all()


# ---------------------------------------------------------------------------
# engine equivalence: partitioned == replicated, bit-identical
# ---------------------------------------------------------------------------

STORES = [
    ("raw", dict(store="raw")),
    ("odag", dict(store="odag")),
    ("spill", dict(store="raw", device_budget_bytes=2048)),
]
APPS = [
    ("motifs", lambda: MotifsApp(max_size=3, collect_embeddings=True)),
    ("cliques", lambda: CliquesApp(max_size=4, collect_embeddings=True)),
    ("fsm", lambda: FSMApp(support=3, max_size=3, collect_embeddings=True)),
]


@pytest.mark.parametrize("sname,skw", STORES, ids=[s for s, _ in STORES])
@pytest.mark.parametrize("aname,mk", APPS, ids=[a for a, _ in APPS])
def test_partitioned_serial_bit_identical(aname, mk, sname, skw):
    g = G.random_labeled(40, 220, 3, seed=2)
    ref = SuperstepRuntime(g, mk(), RunConfig(**skw)).run()
    got = SuperstepRuntime(
        g, mk(), RunConfig(graph_partition=4, **skw)
    ).run()
    assert got.patterns == ref.patterns
    assert set(got.embeddings) == set(ref.embeddings)
    for s in ref.embeddings:
        assert (
            np.sort(np.asarray(got.embeddings[s]), axis=0)
            == np.sort(np.asarray(ref.embeddings[s]), axis=0)
        ).all()


def test_partitioned_pallas_interpret_bit_identical():
    g = G.random_labeled(40, 220, 3, seed=2)
    app = MotifsApp(max_size=3)
    ref = SuperstepRuntime(g, app, RunConfig()).run()
    got = SuperstepRuntime(
        g, MotifsApp(max_size=3),
        RunConfig(graph_partition=4, use_pallas=True, pallas_interpret=True,
                  compact_kernel=True),
    ).run()
    assert got.patterns == ref.patterns


def test_partitioned_device_aggregate_bit_identical():
    g = G.random_labeled(40, 220, 3, seed=2)
    ref = SuperstepRuntime(g, MotifsApp(max_size=3), RunConfig()).run()
    got = SuperstepRuntime(
        g, MotifsApp(max_size=3),
        RunConfig(graph_partition=4, device_aggregate=True),
    ).run()
    assert got.patterns == ref.patterns


# ---------------------------------------------------------------------------
# satellite: agg_qcap growth through the corruption-flag drain
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("qcap", [1, 2, 7])
def test_agg_qcap_grows_instead_of_disabling(qcap):
    """A labeled graph whose distinct quick codes overflow a tiny agg_qcap
    must GROW the capacity (pow2) through the existing corruption-flag
    drain and keep carried partials enabled — not silently fall back."""
    g = G.random_labeled(40, 220, 3, seed=2)
    ref = SuperstepRuntime(g, MotifsApp(max_size=3), RunConfig()).run()
    rt = SuperstepRuntime(
        g, MotifsApp(max_size=3),
        RunConfig(device_aggregate=True, agg_qcap=qcap),
    )
    got = rt.run()
    assert got.patterns == ref.patterns
    assert rt.backend.with_aggregates          # never self-disabled
    assert rt.backend._agg_qcap > qcap         # capacity actually grew
    assert rt.backend._agg_qcap & (rt.backend._agg_qcap - 1) == 0  # pow2


# ---------------------------------------------------------------------------
# checkpoint: layout recorded; replicated checkpoint resumes partitioned
# ---------------------------------------------------------------------------

def test_checkpoint_records_layout_and_restores_across_layouts(tmp_path):
    from repro.core.runtime import checkpoint as ckpt_lib

    g = G.random_labeled(40, 220, 3, seed=2)
    pg = G.to_partitioned(g, 4)
    assert ckpt_lib.graph_layout(G.to_device(g)) == "replicated"
    assert ckpt_lib.graph_layout(pg).startswith("partitioned:w=4:")
    # content fingerprint is layout-independent: elastic restore across
    # layouts re-partitions without invalidating the checkpoint
    assert ckpt_lib.graph_fingerprint(G.to_device(g)) == (
        ckpt_lib.graph_fingerprint(pg)
    )

    ref = SuperstepRuntime(g, MotifsApp(max_size=3), RunConfig()).run()
    ck = str(tmp_path / "ck")
    SuperstepRuntime(
        g, MotifsApp(max_size=3),
        RunConfig(checkpoint_dir=ck, checkpoint_every=1),
    ).run()
    path = ckpt_lib.latest_checkpoint(ck)
    assert ckpt_lib.load(path).graph_layout == "replicated"
    resumed = SuperstepRuntime(
        g, MotifsApp(max_size=3), RunConfig(graph_partition=4)
    ).resume(path)
    assert resumed.patterns == ref.patterns


# ---------------------------------------------------------------------------
# shard-map mesh: halo exchange inside the one-program superstep
# ---------------------------------------------------------------------------

SHARD_SCRIPT = textwrap.dedent(
    """
    import json
    import jax
    import numpy as np
    from repro.core import graph as G, RunConfig, SuperstepRuntime
    from repro.core.apps import MotifsApp, FSMApp, CliquesApp
    from repro.core.runtime.shard import ShardMapBackend

    mesh = jax.make_mesh((8,), ("data",))
    assert len(jax.devices()) == 8
    g = G.random_labeled(40, 220, n_labels=3, seed=2)
    out = {}
    for name, mk, kw in [
        ("motifs-a2a", lambda: MotifsApp(max_size=3), dict(halo="alltoall")),
        ("motifs-gather", lambda: MotifsApp(max_size=3), dict(halo="gather")),
        ("fsm-odag", lambda: FSMApp(support=3, max_size=3),
         dict(store="odag")),
        ("motifs-spill", lambda: MotifsApp(max_size=3),
         dict(store="raw", device_budget_bytes=2048)),
        ("cliques", lambda: CliquesApp(max_size=4, collect_embeddings=True),
         dict()),
        ("motifs-devagg", lambda: MotifsApp(max_size=3),
         dict(device_aggregate=True)),
    ]:
        ref = SuperstepRuntime(g, mk(), RunConfig()).run()
        got = SuperstepRuntime(
            g, mk(), RunConfig(graph_partition=8, **kw),
            backend=ShardMapBackend(mesh),
        ).run()
        emb_ok = set(got.embeddings) == set(ref.embeddings) and all(
            (np.sort(np.asarray(got.embeddings[s]), axis=0)
             == np.sort(np.asarray(ref.embeddings[s]), axis=0)).all()
            for s in ref.embeddings
        )
        out[name] = {
            "match": got.patterns == ref.patterns and emb_ok,
            "syncs": max(s.n_host_syncs for s in got.stats.steps),
            "collective_bytes": sum(
                s.collective_bytes for s in got.stats.steps
            ),
        }
    # partition count must match the mesh
    try:
        SuperstepRuntime(
            g, MotifsApp(max_size=3), RunConfig(graph_partition=4),
            backend=ShardMapBackend(mesh),
        ).run()
        out["mismatch_raises"] = False
    except ValueError:
        out["mismatch_raises"] = True
    print("RESULT" + json.dumps(out))
    """
)


@pytest.mark.slow
def test_partitioned_shard_map_8dev():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-W", "ignore", "-c", SHARD_SCRIPT],
        env=env,
        capture_output=True,
        text=True,
        timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    out = json.loads(line[len("RESULT"):])
    for name, res in out.items():
        if name == "mismatch_raises":
            assert res
            continue
        assert res["match"], name
        # the halo exchange lives inside the one-program superstep: still
        # at most the calibration + count syncs, and its bytes are counted
        assert res["syncs"] <= 2, name
        assert res["collective_bytes"] > 0, name
