"""Beyond-core paper features: §5.3 cost-annotated ODAG partitioning and
maximal-clique mining (§2 generalisation)."""
import networkx as nx
import numpy as np

from repro.core import EngineConfig, graph as G, run, to_device
from repro.core import odag
from repro.core.apps import CliquesApp, MotifsApp
from repro.core.apps.cliques import maximal_cliques


def test_odag_cost_partitioning_covers_and_balances():
    g = G.random_labeled(80, 250, n_labels=1, seed=7)
    dg = to_device(g)
    res = run(g, MotifsApp(max_size=3, collect_embeddings=True),
              EngineConfig(chunk_size=4096, initial_capacity=8192))
    emb = res.embeddings[3]
    o = odag.build(emb)

    for n_workers in (2, 4, 7):
        masks = odag.partition_by_cost(o, n_workers)
        # partition: disjoint + complete over the first-level domain
        stacked = np.stack(masks)
        assert (stacked.sum(axis=0) == 1).all()
        # union of per-worker extractions == full extraction
        parts = [odag.extract_partition(dg, o, m) for m in masks]
        got = set()
        for p in parts:
            rows = set(map(tuple, p.tolist()))
            assert not (rows & got)  # no duplicated work across workers
            got |= rows
        assert got == set(map(tuple, emb.tolist()))
        # balance: no worker above 2.5x the mean estimated cost
        cost = np.ones(len(o.domains[-1]), dtype=np.int64)
        for c in reversed(o.conn):
            cost = c @ cost
        worker_costs = [int(cost[m].sum()) for m in masks]
        assert max(worker_costs) <= 2.5 * (sum(worker_costs) / n_workers)


def test_maximal_cliques_match_networkx():
    g = G.random_labeled(40, 160, n_labels=1, seed=3)
    dg = to_device(g)
    res = run(g, CliquesApp(max_size=4), EngineConfig())
    ours = maximal_cliques(res, dg)
    gx = g.to_networkx()
    want = {}
    for c in nx.find_cliques(gx):
        if len(c) <= 4:
            want.setdefault(len(c), set()).add(frozenset(c))
    for size, arr in ours.items():
        got = {frozenset(int(x) for x in row) for row in arr}
        # sizes < max_size are exact; at max_size our "maximal" means
        # no common neighbour, same as nx for cliques of that size
        if size < 4:
            assert got == want.get(size, set()), size
