import warnings

warnings.filterwarnings("ignore", category=UserWarning)
