# Intentionally minimal. The seed's blanket UserWarning suppression was
# removed so real JAX deprecation signals surface; targeted filters belong
# in pyproject.toml's pytest config if ever needed.
