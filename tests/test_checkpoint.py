"""Superstep-granular checkpoint/resume tests (DESIGN.md §9).

The acceptance contract: a run checkpointed at superstep k and resumed
reproduces the uninterrupted run's ``patterns`` dicts and embedding *sets*
(not row order — ODAG resurrection reorders) for motifs / cliques / FSM
across raw / ODAG / spill stores on both execution backends, including
resuming under a *different* worker count (elastic restore). Plus store
``state_dict`` round-trips, fingerprint guards, cadence, and atomicity
details. Graphs stay ~40 vertices (engine runs are seconds each)."""
import glob
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core import (
    EngineConfig,
    RunConfig,
    SuperstepRuntime,
    graph as G,
    resume,
    run,
    to_device,
)
from repro.core.apps import CliquesApp, FSMApp, MotifsApp
from repro.core.distributed import DistConfig, run_distributed
from repro.core.runtime import (
    SerialBackend,
    ShardMapBackend,
    latest_checkpoint,
)
from repro.core.runtime import checkpoint as ckpt_lib
from repro.core.store import ODAGStore, RawStore, SpillStore


def _emb_sets(res):
    return {k: set(map(tuple, v.tolist())) for k, v in res.embeddings.items()}


def _assert_same(base, other):
    assert base.patterns == other.patterns
    assert _emb_sets(base) == _emb_sets(other)


def _ckpts(td):
    return sorted(glob.glob(os.path.join(td, "ckpt-step*.npz")))


# ---------------------------------------------------------------------------
# store state_dict round-trips
# ---------------------------------------------------------------------------

def test_raw_store_state_roundtrip():
    s = RawStore()
    rows = np.arange(12, dtype=np.int32).reshape(4, 3)
    s.append(rows)
    s.seal(3)
    sd = s.state_dict()
    t = RawStore()
    t.from_state_dict(sd)
    assert t.n_rows == 4 and t.size == 3
    np.testing.assert_array_equal(t.materialize(), rows)
    # empty frontier keeps its width through the round trip
    s.seal(4)
    t2 = RawStore()
    t2.from_state_dict(s.state_dict())
    assert t2.n_rows == 0 and t2.size == 4


def test_odag_store_state_roundtrip():
    g = to_device(G.random_labeled(40, 90, n_labels=1, seed=2))
    res = run(
        G.random_labeled(40, 90, n_labels=1, seed=2),
        MotifsApp(max_size=3, collect_embeddings=True),
        EngineConfig(),
    )
    emb = res.embeddings[3]
    s = ODAGStore(g)
    s.append(emb)
    s.seal(3)
    sd = s.state_dict()
    t = ODAGStore(g)
    t.from_state_dict(sd)
    assert t.n_rows == s.n_rows and t.size == 3
    assert t.stored_bytes == s.stored_bytes
    assert (
        set(map(tuple, t.materialize().tolist()))
        == set(map(tuple, s.materialize().tolist()))
    )


def test_spill_store_state_delegates_to_inner():
    """A spill-wrapped checkpoint is byte-identical to the inner store's:
    runs may resume with a different (or no) device budget."""
    inner = RawStore()
    inner.append(np.arange(20, dtype=np.int32).reshape(10, 2))
    inner.seal(2)
    s = SpillStore(inner, device_budget_bytes=3 * 2 * 4)
    sd = s.state_dict()
    assert sd["kind"] == "raw"
    plain = RawStore()
    plain.from_state_dict(sd)
    np.testing.assert_array_equal(plain.materialize(), inner.materialize())


def test_store_kind_mismatch_raises():
    s = RawStore()
    s.append(np.zeros((2, 2), np.int32))
    s.seal(2)
    g = to_device(G.triangle_plus_tail())
    with pytest.raises(ValueError, match="store"):
        ODAGStore(g).from_state_dict(s.state_dict())


# ---------------------------------------------------------------------------
# acceptance: resume == uninterrupted, all apps x all stores x both backends
# ---------------------------------------------------------------------------

APPS = [
    ("motifs", lambda: MotifsApp(max_size=3, collect_embeddings=True)),
    ("cliques", lambda: CliquesApp(max_size=4, collect_embeddings=True)),
    ("fsm", lambda: FSMApp(support=3, max_size=3, collect_embeddings=True)),
]
STORES = [
    ("raw", dict(store="raw")),
    ("odag", dict(store="odag")),
    ("spill", dict(store="raw", device_budget_bytes=2048)),
]
SMALL = dict(chunk_size=64, initial_capacity=64)


@pytest.mark.parametrize("sname,skw", STORES, ids=[s[0] for s in STORES])
@pytest.mark.parametrize("aname,mk", APPS, ids=[a[0] for a in APPS])
def test_serial_resume_equals_uninterrupted(aname, mk, sname, skw, tmp_path):
    g = G.random_labeled(40, 90, n_labels=3, seed=3)
    ref = run(
        g, mk(), EngineConfig(**SMALL, **skw, checkpoint_dir=str(tmp_path))
    )
    files = _ckpts(str(tmp_path))
    assert files, "run wrote no checkpoints"
    # resume from the EARLIEST cut: replays the longest tail
    resumed = resume(g, mk(), files[0], EngineConfig(**SMALL, **skw))
    _assert_same(ref, resumed)
    # and from the latest (directory resolution)
    resumed2 = resume(g, mk(), str(tmp_path), EngineConfig(**SMALL, **skw))
    _assert_same(ref, resumed2)


@pytest.mark.parametrize("sname,skw", STORES, ids=[s[0] for s in STORES])
@pytest.mark.parametrize(
    "aname,mk",
    [APPS[0], APPS[2]],  # motifs (counts) + fsm (domains/alpha), edge cases
    ids=["motifs", "fsm"],
)
def test_shard_resume_equals_uninterrupted(aname, mk, sname, skw, tmp_path):
    mesh = jax.make_mesh((1,), ("data",))
    g = G.random_labeled(40, 90, n_labels=3, seed=3)
    ref = run(g, mk(), EngineConfig())
    interrupted = run_distributed(
        g, mk(), mesh, DistConfig(store=skw["store"],
                                  checkpoint_dir=str(tmp_path))
    )
    _assert_same(ref, interrupted)
    files = _ckpts(str(tmp_path))
    assert files
    resumed = resume(
        g, mk(), files[0], DistConfig(store=skw["store"]),
        ShardMapBackend(mesh),
    )
    _assert_same(ref, resumed)


def test_cross_backend_elastic_resume(tmp_path):
    """A checkpoint is backend-free: serial cut -> shard-map resume and
    shard-map cut -> serial resume both reproduce the uninterrupted run."""
    mesh = jax.make_mesh((1,), ("data",))
    g = G.random_labeled(40, 90, n_labels=3, seed=7)
    mk = lambda: MotifsApp(max_size=4, collect_embeddings=True)
    ref = run(g, mk(), EngineConfig())

    ser_dir = tmp_path / "ser"
    run(g, mk(), EngineConfig(checkpoint_dir=str(ser_dir)))
    resumed = resume(
        g, mk(), _ckpts(str(ser_dir))[0], DistConfig(), ShardMapBackend(mesh)
    )
    _assert_same(ref, resumed)

    dist_dir = tmp_path / "dist"
    run_distributed(
        g, mk(), mesh, DistConfig(store="odag", checkpoint_dir=str(dist_dir))
    )
    resumed = resume(
        g, mk(), _ckpts(str(dist_dir))[0], EngineConfig(store="odag")
    )
    _assert_same(ref, resumed)


def test_elastic_worker_parts_from_checkpoint(tmp_path):
    """The store payload is worker-count-free: restoring one checkpoint and
    re-partitioning for W-1, W, W+1 workers covers the identical row set
    (what makes a different-mesh resume elastic by construction)."""
    g = G.random_labeled(40, 90, n_labels=3, seed=9)
    dg = to_device(g)
    run(
        g, MotifsApp(max_size=4),
        EngineConfig(store="odag", checkpoint_dir=str(tmp_path)),
    )
    state = ckpt_lib.load(_ckpts(str(tmp_path))[-1])
    rows = None
    for w in (1, 2, 3):
        store = ODAGStore(dg)
        store.from_state_dict(state.store_state)
        parts = store.worker_parts(w)
        assert len(parts) == w
        got = set(map(tuple, np.concatenate(parts, axis=0).tolist()))
        if rows is None:
            rows = got
        assert got == rows
    assert rows


# ---------------------------------------------------------------------------
# cadence, fingerprints, file handling
# ---------------------------------------------------------------------------

def test_checkpoint_every_cadence(tmp_path):
    g = G.random_labeled(40, 120, n_labels=2, seed=11)
    run(
        g, MotifsApp(max_size=4),
        EngineConfig(checkpoint_dir=str(tmp_path), checkpoint_every=2),
    )
    steps = [
        int(os.path.basename(f)[len("ckpt-step"):-len(".npz")])
        for f in _ckpts(str(tmp_path))
    ]
    assert steps, "no checkpoints written"
    # cursor step k+1 is written after completing superstep k; cadence 2
    # keeps even completed steps only
    assert all((s - 1) % 2 == 0 for s in steps)


def test_latest_checkpoint_resolution(tmp_path):
    assert latest_checkpoint(str(tmp_path)) is None
    assert latest_checkpoint(str(tmp_path / "missing")) is None
    for step in (2, 10, 3):
        open(tmp_path / f"ckpt-step{step:04d}.npz", "wb").close()
    (tmp_path / "not-a-checkpoint.npz").touch()
    got = latest_checkpoint(str(tmp_path))
    assert os.path.basename(got) == "ckpt-step0010.npz"


def test_fingerprint_guards(tmp_path):
    g = G.random_labeled(40, 90, n_labels=2, seed=13)
    run(g, MotifsApp(max_size=4), EngineConfig(checkpoint_dir=str(tmp_path)))
    path = _ckpts(str(tmp_path))[0]
    with pytest.raises(ValueError, match="different app"):
        resume(g, MotifsApp(max_size=3), path)
    with pytest.raises(ValueError, match="different graph"):
        resume(G.random_labeled(40, 90, n_labels=2, seed=14),
               MotifsApp(max_size=4), path)
    with pytest.raises(FileNotFoundError):
        resume(g, MotifsApp(max_size=4), str(tmp_path / "empty"))


def test_checkpoint_is_single_atomic_file(tmp_path):
    g = G.random_labeled(40, 90, n_labels=2, seed=15)
    res = run(
        g, MotifsApp(max_size=3),
        EngineConfig(checkpoint_dir=str(tmp_path)),
    )
    files = os.listdir(tmp_path)
    assert all(f.startswith("ckpt-step") and f.endswith(".npz") for f in files)
    assert not any(".tmp-" in f for f in files), "torn staging file left"
    # checkpoint cost is observable per step
    assert any(s.t_checkpoint > 0 for s in res.stats.steps)
    assert all(s.t_checkpoint == 0 for s in res.stats.steps[-1:])


def test_resume_preserves_stats_history(tmp_path):
    g = G.random_labeled(40, 90, n_labels=2, seed=17)
    ref = run(g, MotifsApp(max_size=4),
              EngineConfig(checkpoint_dir=str(tmp_path)))
    resumed = resume(g, MotifsApp(max_size=4), _ckpts(str(tmp_path))[0])
    assert [s.step for s in resumed.stats.steps] == [
        s.step for s in ref.stats.steps
    ]
    assert resumed.stats.total_embeddings == ref.stats.total_embeddings
    assert len(resumed.aggregates) == len(ref.aggregates)
    np.testing.assert_array_equal(
        resumed.aggregates[-1].counts, ref.aggregates[-1].counts
    )


# ---------------------------------------------------------------------------
# §13 kill matrix: crash at EVERY phase boundary x both backends — the
# supervised run must equal the uninterrupted one bit-identically
# ---------------------------------------------------------------------------

from repro.core import run_supervised  # noqa: E402  (§13 additions)
from repro.core.runtime import FaultPlan, FaultSpec  # noqa: E402
from repro.core.runtime import faults as faults_lib  # noqa: E402

KILL_PHASES = (
    "materialize", "aggregate", "alpha", "expand", "seal", "checkpoint",
)


def _km_graph():
    return G.random_labeled(40, 90, n_labels=3, seed=3)


def _km_app():
    return MotifsApp(max_size=3, collect_embeddings=True)


_KM_CLEAN = {}


def _km_clean(backend):
    if backend not in _KM_CLEAN:
        if backend == "serial":
            _KM_CLEAN[backend] = run(_km_graph(), _km_app(),
                                     EngineConfig(**SMALL))
        else:
            _KM_CLEAN[backend] = run_distributed(
                _km_graph(), _km_app(), jax.make_mesh((1,), ("data",)),
                DistConfig(),
            )
    return _KM_CLEAN[backend]


@pytest.mark.parametrize("phase", KILL_PHASES)
def test_kill_matrix_serial(phase, tmp_path):
    plan = FaultPlan([FaultSpec(phase, 2, "crash")])
    res = run_supervised(
        _km_graph(), _km_app(),
        EngineConfig(**SMALL, faults=plan, checkpoint_dir=str(tmp_path)),
    )
    assert plan.fired == [(phase, 2, "crash")], "fault did not trip"
    _assert_same(_km_clean("serial"), res)
    assert res.recovery["n_retries"] == 1
    assert res.recovery["degradations"] == []


@pytest.mark.parametrize("phase", KILL_PHASES)
def test_kill_matrix_shard(phase, tmp_path):
    plan = FaultPlan([FaultSpec(phase, 2, "crash")])
    res = run_supervised(
        _km_graph(), _km_app(),
        DistConfig(faults=plan, checkpoint_dir=str(tmp_path)),
        ShardMapBackend(jax.make_mesh((1,), ("data",))),
    )
    assert plan.fired == [(phase, 2, "crash")], "fault did not trip"
    _assert_same(_km_clean("shard"), res)
    assert res.recovery["n_retries"] == 1


# ---------------------------------------------------------------------------
# real process death (kind "exit"): the in-process matrix above raises;
# this one actually kills the interpreter mid-superstep, then a fresh
# process resumes from the surviving cut
# ---------------------------------------------------------------------------

KILL_SCRIPT = textwrap.dedent(
    """
    import sys
    from repro.core import EngineConfig, graph as G, run
    from repro.core.apps import MotifsApp
    from repro.core.runtime import FaultPlan, FaultSpec

    plan = FaultPlan([FaultSpec(sys.argv[2], int(sys.argv[3]), "exit")])
    run(
        G.random_labeled(40, 90, n_labels=3, seed=3),
        MotifsApp(max_size=3, collect_embeddings=True),
        EngineConfig(chunk_size=64, initial_capacity=64,
                     checkpoint_dir=sys.argv[1], faults=plan),
    )
    raise SystemExit("fault never tripped")
    """
)


def test_kill_matrix_real_process_death(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-W", "ignore", "-c", KILL_SCRIPT,
         str(tmp_path), "seal", "2"],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == faults_lib.EXIT_CODE, proc.stderr[-3000:]
    # no torn staging file survives the kill; the cut before it does
    assert _ckpts(str(tmp_path)), "no checkpoint survived the kill"
    resumed = resume(
        _km_graph(), _km_app(), str(tmp_path), EngineConfig(**SMALL)
    )
    _assert_same(_km_clean("serial"), resumed)


# ---------------------------------------------------------------------------
# stale staging-file sweep (§13 satellite): orphaned *.tmp-* checkpoints
# from a killed writer are removed on resume, never mistaken for cuts
# ---------------------------------------------------------------------------

def test_stale_tmp_swept_on_resume(tmp_path):
    g = _km_graph()
    ref = run(
        g, _km_app(), EngineConfig(**SMALL, checkpoint_dir=str(tmp_path))
    )
    orphan = tmp_path / "ckpt-step0002.npz.tmp-9999.npz"
    orphan.write_bytes(b"torn half-written payload")
    bystander = tmp_path / "unrelated.npz"
    bystander.write_bytes(b"not a staging file")
    resumed = resume(g, _km_app(), str(tmp_path), EngineConfig(**SMALL))
    assert not orphan.exists(), "orphaned staging file survived resume"
    assert bystander.exists(), "sweep removed a non-staging file"
    _assert_same(ref, resumed)


def test_sweep_stale_tmp_direct(tmp_path):
    from repro.core.runtime import sweep_stale_tmp

    orphan = tmp_path / "ckpt-step0007.npz.tmp-12345.npz"
    orphan.write_bytes(b"x")
    (tmp_path / "ckpt-step0007.npz").write_bytes(b"real cut")
    removed = sweep_stale_tmp(str(tmp_path))
    assert [os.path.basename(p) for p in removed] == [orphan.name]
    assert (tmp_path / "ckpt-step0007.npz").exists()
    assert sweep_stale_tmp(str(tmp_path / "missing")) == []


# ---------------------------------------------------------------------------
# elastic restore on a real multi-device mesh (subprocess, @slow)
# ---------------------------------------------------------------------------

ELASTIC_SCRIPT = textwrap.dedent(
    """
    import glob, json, os, tempfile
    import numpy as np
    import jax
    from jax.sharding import Mesh
    from repro.core import EngineConfig, graph as G, resume, run
    from repro.core.apps import FSMApp, MotifsApp
    from repro.core.distributed import DistConfig, run_distributed
    from repro.core.runtime import ShardMapBackend

    assert len(jax.devices()) == 8
    def mesh_of(w):
        return Mesh(np.array(jax.devices()[:w]), ("data",))

    g = G.random_labeled(60, 150, n_labels=3, seed=3)
    out = {}
    for name, mk in [
        ("motifs", lambda: MotifsApp(max_size=4)),
        ("fsm", lambda: FSMApp(support=3, max_size=3)),
    ]:
        ref = run(g, mk(), EngineConfig())
        with tempfile.TemporaryDirectory() as td:
            # checkpoint under W=2 workers...
            run_distributed(
                g, mk(), mesh_of(2),
                DistConfig(store="odag", checkpoint_dir=td),
            )
            first = sorted(glob.glob(os.path.join(td, "ckpt-step*.npz")))[0]
            # ...resume under W-1=1 and W+1=3 workers (elastic restore)
            matches = {}
            for w in (1, 3):
                res = resume(
                    g, mk(), first, DistConfig(store="odag"),
                    ShardMapBackend(mesh_of(w)),
                )
                matches[w] = res.patterns == ref.patterns
        out[name] = matches
    print("RESULT" + json.dumps(out))
    """
)


@pytest.mark.slow
def test_elastic_restore_different_worker_count_8dev():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-W", "ignore", "-c", ELASTIC_SCRIPT],
        env=env,
        capture_output=True,
        text=True,
        timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    out = json.loads(line[len("RESULT"):])
    assert out["motifs"] == {"1": True, "3": True}
    assert out["fsm"] == {"1": True, "3": True}
