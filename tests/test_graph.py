import numpy as np
import pytest

from repro.core import graph as G, to_device
from repro.core.bitset import pack_bool_matrix, popcount_u32
from repro.core.bitset import test_bit as bit_at  # avoid pytest collection


def test_graph_dedups_and_sorts_edges():
    g = G.Graph(n=3, labels=[0, 1, 2], edges=[[1, 0], [0, 1], [2, 1]])
    assert g.m == 2
    assert (g.edges == np.array([[0, 1], [1, 2]])).all()


def test_self_loops_rejected():
    with pytest.raises(ValueError):
        G.Graph(n=2, labels=[0, 0], edges=[[1, 1]])


def test_csr_and_neighbor_table():
    g = G.triangle_plus_tail()
    nbr, ned, deg = g.neighbor_table()
    assert deg.tolist() == [2, 2, 3, 2, 1]
    assert sorted(nbr[2][nbr[2] >= 0].tolist()) == [0, 1, 3]
    # edge-id table consistent with endpoints
    for v in range(g.n):
        for j in range(nbr.shape[1]):
            if nbr[v, j] >= 0:
                u, w = g.edges[ned[v, j]]
                assert {v, int(nbr[v, j])} == {int(u), int(w)}


def test_neighbor_table_matches_per_vertex_loop():
    """The vectorised scatter must reproduce the reference per-vertex loop
    exactly (same CSR order, same padding) on the fixture graphs."""

    def loop_table(g):
        indptr, indices, eids = g.csr()
        deg = (indptr[1:] - indptr[:-1]).astype(np.int32)
        d = max(1, int(deg.max()) if g.n else 1)
        nbr = np.full((g.n, d), -1, dtype=np.int32)
        ned = np.full((g.n, d), -1, dtype=np.int32)
        for vtx in range(g.n):
            s, t = indptr[vtx], indptr[vtx + 1]
            nbr[vtx, : t - s] = indices[s:t]
            ned[vtx, : t - s] = eids[s:t]
        return nbr, ned, deg

    fixtures = [
        G.paper_figure2(),
        G.triangle_plus_tail(),
        G.complete(5),
        G.random_labeled(40, 90, 3, seed=2),
        G.Graph(n=3, labels=np.zeros(3), edges=np.zeros((0, 2), np.int32)),
        # single hub: star graph (max-degree vertex dominates the table)
        G.Graph(
            n=6,
            labels=np.zeros(6),
            edges=np.array([[0, v] for v in range(1, 6)], np.int32),
        ),
    ]
    for g in fixtures:
        got = g.neighbor_table()
        want = loop_table(g)
        for a, b in zip(got, want):
            assert a.shape == b.shape
            assert (a == b).all()


def test_adjacency_bitmap_matches_edges():
    g = G.random_labeled(50, 120, 3, seed=0)
    dg = to_device(g)
    es = {(int(u), int(v)) for u, v in g.edges}
    for u in range(g.n):
        for v in range(g.n):
            expect = (min(u, v), max(u, v)) in es and u != v
            assert bool(dg.is_edge(u, v)) == expect or not expect
    # spot-check exact equality on all pairs via dense reconstruction
    dense = np.zeros((g.n, g.n), bool)
    for u, v in g.edges:
        dense[u, v] = dense[v, u] = True
    got = np.array(
        [[bool(bit_at(dg.adj_bits, u, v)) for v in range(g.n)] for u in range(g.n)]
    )
    assert (got == dense).all()


def test_bitset_popcount():
    x = np.array([0, 1, 3, 0xFFFFFFFF], dtype=np.uint32)
    import jax.numpy as jnp

    assert popcount_u32(jnp.asarray(x)).tolist() == [0, 1, 2, 32]


def test_pack_bool_roundtrip():
    rng = np.random.default_rng(0)
    dense = rng.random((5, 70)) < 0.3
    packed = pack_bool_matrix(dense)
    import jax.numpy as jnp

    for r in range(5):
        for c in range(70):
            assert bool(bit_at(jnp.asarray(packed), r, c)) == bool(dense[r, c])


def test_generators_shapes():
    g = G.citeseer_like(scale=0.05)
    assert g.n > 100 and g.m > 100
    assert g.labels.max() < 6
    g2 = G.mico_like(scale=0.005)
    assert g2.labels.max() < 29
